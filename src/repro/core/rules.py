"""Attribution backward rules at nonlinearities (paper §II, Eq. 3-5, Fig. 4).

The three gradient-backprop feature-attribution methods differ ONLY in how the
gradient signal crosses a rectifier nonlinearity:

  saliency   : R_L = (f > 0) . R_{L+1}             (Eq. 3; needs 1-bit mask of f)
  deconvnet  : R_L = (R_{L+1} > 0) . R_{L+1}       (Eq. 4; needs NO residual)
  guided     : R_L = (f>0).(R>0) . R_{L+1}         (Eq. 5; needs 1-bit mask of f)

The paper's FPGA stores the mask as 1 bit/element in BRAM.  Here each rule is
a ``jax.custom_vjp`` whose residual is a bit-packed ``uint8`` tensor
(:mod:`repro.core.masks`) — XLA then *cannot* cache the full activation, so the
memory claim holds by construction, not by hoping DCE fires.

``method="autodiff"`` is the plain op (used for training); ``"saliency"`` is
numerically identical to autodiff for ReLU (the mask IS the exact derivative),
which the tests assert.

Beyond-paper generalization: modern backbones use smooth gates (SiLU/GELU)
whose derivative needs the pre-activation *value*, so a 1-bit mask is
insufficient.  We generalize the paper's idea — "store the cheapest sufficient
residual" — with per-row int8-quantized residuals (``residual="int8"``), and
note that the DeconvNet rule still needs zero residuals on any nonlinearity.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import masks

METHODS = ("autodiff", "saliency", "deconvnet", "guided")
RESIDUALS = ("exact", "int8")


# ---------------------------------------------------------------------------
# int8 residual quantization (beyond-paper; see DESIGN.md §4)
# ---------------------------------------------------------------------------

def quantize_int8(x: jnp.ndarray):
    """Per-row (last-axis) absmax int8 quantization. Returns (q, scale)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# ReLU — the paper's exact rules
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _relu_attr(x, method: str):
    return jax.nn.relu(x)


def _relu_attr_fwd(x, method: str):
    y = jax.nn.relu(x)
    if method == "deconvnet":
        res = None                      # Table II: DeconvNet stores no ReLU mask
    else:
        res = masks.pack_mask(x > 0)    # 1-bit mask, 16x smaller than bf16 f
    return y, res


def _relu_attr_bwd(method: str, res, g):
    # The cotangent g has the primal's shape/dtype — no static aux needed.
    if method == "deconvnet":
        r = jnp.where(g > 0, g, 0)                        # Eq. 4
    elif method == "guided":
        m = masks.unpack_mask(res, g.shape[-1])
        r = jnp.where(m & (g > 0), g, 0)                  # Eq. 5
    else:  # saliency — exact ReLU vjp
        m = masks.unpack_mask(res, g.shape[-1])
        r = jnp.where(m, g, 0)                            # Eq. 3
    return (r.astype(g.dtype),)


_relu_attr.defvjp(_relu_attr_fwd, _relu_attr_bwd)


def relu(x: jnp.ndarray, method: str = "autodiff") -> jnp.ndarray:
    if method == "autodiff":
        return jax.nn.relu(x)
    if method not in METHODS:
        raise ValueError(f"unknown attribution method {method!r}")
    return _relu_attr(x, method)


# ---------------------------------------------------------------------------
# Smooth gates (SiLU / GELU / sigmoid / softplus) — beyond-paper residuals
# ---------------------------------------------------------------------------

_FWD = {
    "silu": jax.nn.silu,
    "gelu": partial(jax.nn.gelu, approximate=True),
    "sigmoid": jax.nn.sigmoid,
    "softplus": jax.nn.softplus,
}


def _derivative(kind: str, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "silu":
        s = jax.nn.sigmoid(x)
        return s * (1 + x * (1 - s))
    if kind == "gelu":
        # tanh-approximate GELU derivative
        c = 0.7978845608028654  # sqrt(2/pi)
        t = jnp.tanh(c * (x + 0.044715 * x**3))
        return 0.5 * (1 + t) + 0.5 * x * (1 - t**2) * c * (1 + 3 * 0.044715 * x**2)
    if kind == "sigmoid":
        s = jax.nn.sigmoid(x)
        return s * (1 - s)
    if kind == "softplus":
        return jax.nn.sigmoid(x)
    raise ValueError(kind)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _smooth_attr(x, kind: str, method: str, residual: str):
    return _FWD[kind](x)


def _smooth_attr_fwd(x, kind: str, method: str, residual: str):
    y = _FWD[kind](x)
    if method == "deconvnet":
        res = None                      # gradient-side rule only: zero residual
    elif residual == "int8":
        res = quantize_int8(x)          # 2x smaller than bf16, 4x than f32
    else:
        res = x
    return y, res


def _smooth_attr_bwd(kind: str, method: str, residual: str, res, g):
    if method == "deconvnet":
        # Generalized Eq. 4: rectify the gradient signal, ignore local slope.
        return (jnp.where(g > 0, g, 0).astype(g.dtype),)
    if residual == "int8":
        x = dequantize_int8(*res, jnp.float32)
    else:
        x = res.astype(jnp.float32)
    d = _derivative(kind, x)
    r = g.astype(jnp.float32) * d
    if method == "guided":
        # Generalized Eq. 5: local slope AND gradient rectification.
        r = jnp.where(g > 0, r, 0)
    return (r.astype(g.dtype),)


_smooth_attr.defvjp(_smooth_attr_fwd, _smooth_attr_bwd)


def act(x: jnp.ndarray, kind: str, method: str = "autodiff",
        residual: str = "int8") -> jnp.ndarray:
    """Attribution-aware nonlinearity dispatch used by every model in the zoo."""
    if kind == "relu":
        return relu(x, method)
    if method == "autodiff":
        return _FWD[kind](x)
    if method not in METHODS:
        raise ValueError(f"unknown attribution method {method!r}")
    if residual not in RESIDUALS:
        raise ValueError(f"unknown residual policy {residual!r}")
    return _smooth_attr(x, kind, method, residual)


def silu(x, method="autodiff", residual="int8"):
    return act(x, "silu", method, residual)


def gelu(x, method="autodiff", residual="int8"):
    return act(x, "gelu", method, residual)


# ---------------------------------------------------------------------------
# 2x2 max-pool with 2-bit argmax residual (paper §III.D, Fig. 5)
# ---------------------------------------------------------------------------

def _pool_windows(x: jnp.ndarray) -> jnp.ndarray:
    """NHWC -> [N, H/2, W/2, C, 4] window view (2x2, stride 2, no overlap)."""
    n, h, w, c = x.shape
    xw = x.reshape(n, h // 2, 2, w // 2, 2, c)
    xw = xw.transpose(0, 1, 3, 5, 2, 4)          # [N, H/2, W/2, C, 2, 2]
    return xw.reshape(n, h // 2, w // 2, c, 4)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _maxpool_attr(x, method: str):
    return jnp.max(_pool_windows(x), axis=-1)


def _maxpool_attr_fwd(x, method: str):
    xw = _pool_windows(x)
    idx = jnp.argmax(xw, axis=-1)                # 0..3 — the paper's 2-bit index
    y = jnp.max(xw, axis=-1)
    return y, masks.pack_crumbs(idx)


def _maxpool_attr_bwd(method: str, packed, g):
    n, hp, wp, c = g.shape                        # pooled shape -> input shape
    idx = masks.unpack_crumbs(packed, c)          # [N, H/2, W/2, C]
    routed = jax.nn.one_hot(idx, 4, dtype=g.dtype) * g[..., None]
    routed = routed.reshape(n, hp, wp, c, 2, 2)
    routed = routed.transpose(0, 1, 4, 2, 5, 3)   # [N, H/2, 2, W/2, 2, C]
    return (routed.reshape(n, 2 * hp, 2 * wp, c),)


_maxpool_attr.defvjp(_maxpool_attr_fwd, _maxpool_attr_bwd)


def maxpool2x2(x: jnp.ndarray, method: str = "autodiff") -> jnp.ndarray:
    """2x2/stride-2 max-pool; BP is the unpooling of Fig. 5b for every method."""
    if method == "autodiff":
        return jnp.max(_pool_windows(x), axis=-1)
    return _maxpool_attr(x, method)
