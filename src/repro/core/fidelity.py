"""Heatmap-agreement metrics for quantization-fidelity studies (paper §IV).

The paper's claim is that 16-bit fixed point preserves the *explanation*,
not the logits — the right comparison is between attribution heatmaps, and
the metrics the XAI-fidelity literature uses for that (ApproXAI,
arXiv 2504.17929; Pan & Mishra, arXiv 2305.04887) are rank-based, not
value-based: a heatmap is read by which pixels dominate, not by their
absolute magnitudes.

All metrics take two same-shape arrays (typically ``attribution.heatmap``
outputs or raw relevance tensors), flatten them, and return a Python float.
Pure NumPy — no scipy dependency (CI installs jax+pytest only).
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def _flat(a) -> np.ndarray:
    return np.asarray(a, np.float64).reshape(-1)


def rankdata(a: np.ndarray) -> np.ndarray:
    """Ranks (1-based) with ties averaged — scipy-free ``rankdata``."""
    order = np.argsort(a, kind="stable")
    ranks = np.empty(a.size, np.float64)
    ranks[order] = np.arange(1, a.size + 1)
    # average the rank over each tie group
    sa = a[order]
    _, start, counts = np.unique(sa, return_index=True, return_counts=True)
    for s, c in zip(start, counts):
        if c > 1:
            ranks[order[s:s + c]] = ranks[order[s:s + c]].mean()
    return ranks


def spearman(a, b) -> float:
    """Spearman rank correlation in [-1, 1] (ties averaged)."""
    ra, rb = rankdata(_flat(a)), rankdata(_flat(b))
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    if denom == 0.0:
        return 1.0 if np.array_equal(ra, rb) else 0.0
    return float((ra * rb).sum() / denom)


def topk_overlap(a, b, k: int) -> float:
    """|top-k(a) ∩ top-k(b)| / k — do the two maps highlight the same pixels?"""
    fa, fb = _flat(a), _flat(b)
    ta = set(np.argpartition(-fa, k - 1)[:k].tolist())
    tb = set(np.argpartition(-fb, k - 1)[:k].tolist())
    return len(ta & tb) / k


def sign_agreement(a, b) -> float:
    """Fraction of elements whose sign matches (zeros must match zeros)."""
    fa, fb = np.sign(_flat(a)), np.sign(_flat(b))
    return float((fa == fb).mean())


def compare(a, b, *, k: int = 32) -> Dict[str, float]:
    """All three metrics at once — the fidelity row of the README table."""
    return {"spearman": spearman(a, b),
            "topk_overlap": topk_overlap(a, b, k),
            "sign_agreement": sign_agreement(a, b)}
