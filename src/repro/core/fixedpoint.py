"""16-bit fixed-point arithmetic (paper §IV: Q-format 16b weights/acts/grads).

The FPGA runs inference AND gradient backpropagation in 16-bit fixed point.
Two layers of support here:

* **Fake quantization** (:func:`make_quantizer`) — values snapped to a Qm.n
  grid in f32 carriers (straight-through estimator for BP), for quick
  precision studies on any float path.
* **True integer arithmetic** — the Q-format codec (:func:`to_fixed` /
  :func:`from_fixed`), the post-accumulation requantizer
  (:func:`requantize`), and the saturating int16 add (:func:`sat_add`).
  These are the numeric contract of the int16 Pallas kernels
  (``repro.kernels.*.fxp``): Q7.8 int16 operands, int32 MXU accumulation,
  round-half-up right-shift requantization with symmetric saturation.
  :func:`requantize_np` is the independent NumPy mirror the kernel tests
  pin bit-exactness against.

Q-format choices (per-tensor, all 16-bit as in the paper):

* activations / gradients / biases — **Q7.8** (range ±127.996, step 2^-8):
  the paper CNN's activations stay within ±tens.
* weights — **Q1.14** (``WGT_FRAC``): CNN weights live in (-2, 2), so
  spending the idle integer bits on fraction keeps the product scale
  2^(8+14) well inside int32 while giving weights 64x finer steps.
* backward seeds — Q7.8 scaled by ``SEED_GAIN`` (a power of two, i.e. a
  block exponent on the whole BP phase): gradients shrink multiplicatively
  through the layers, and pre-scaling the seed keeps them in the high bits
  of the grid; the final relevance is divided back out exactly.

Saturation is SYMMETRIC at ±(2^15 - 1) grid steps: -2^15 is never produced,
so negation/abs stay closed in int16 — the same convention saturating FPGA
arithmetic uses.  :func:`make_quantizer` deliberately clips to the same
symmetric range (NOT the asymmetric two's-complement [-2^15, 2^15 - 1]);
``tests/test_fixedpoint.py`` pins this.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

ACT_FRAC = 8          # Q7.8 activations / gradients / biases
WGT_FRAC = 14         # Q1.14 weights
SEED_GAIN_BITS = 6    # backward seed pre-scale: 2^6 (removed exactly at the end)
SEED_GAIN = float(1 << SEED_GAIN_BITS)
INT16_LIM = (1 << 15) - 1          # symmetric saturation, grid units


def make_quantizer(int_bits: int = 7, frac_bits: int = 8):
    """Q``int_bits``.``frac_bits`` symmetric fixed-point fake-quantizer.

    Default Q7.8 (1 sign + 7 int + 8 frac = 16 bits), range
    ±(2^15 - 1)/2^8 = ±127.99609375, resolution 2^-8.  The clip is
    symmetric by design — both rails sit at ``2^(int_bits+frac_bits) - 1``
    grid steps, matching the saturating integer kernels (which never emit
    the asymmetric two's-complement minimum).
    """
    scale = float(2 ** frac_bits)
    lim = float(2 ** (int_bits + frac_bits) - 1)

    @jax.custom_vjp
    def q(x):
        return jnp.clip(jnp.round(x * scale), -lim, lim) / scale

    # Straight-through: the FPGA truncates products but propagates gradient
    # signals at full local fidelity across the quantization.
    q.defvjp(lambda x: (q(x), None), lambda _, g: (g,))
    return q


fxp16 = make_quantizer(7, 8)


def quantize_tree(tree, int_bits: int = 7, frac_bits: int = 8):
    """Fake-quantize every leaf of a parameter pytree to Qm.n."""
    q = make_quantizer(int_bits, frac_bits)
    return jax.tree.map(q, tree)


# ---------------------------------------------------------------------------
# true int16 codec + requantizer (the fxp kernels' numeric contract)
# ---------------------------------------------------------------------------


def to_fixed(x: jnp.ndarray, frac_bits: int = ACT_FRAC) -> jnp.ndarray:
    """f32 -> int16 on the Q(15-n).n grid, round-to-nearest-even, saturated."""
    g = jnp.round(x.astype(jnp.float32) * (1 << frac_bits))
    return jnp.clip(g, -INT16_LIM, INT16_LIM).astype(jnp.int16)


def from_fixed(q: jnp.ndarray, frac_bits: int = ACT_FRAC) -> jnp.ndarray:
    """int16 grid values -> f32 (exact: every grid point is an f32)."""
    return q.astype(jnp.float32) / (1 << frac_bits)


def requantize(acc: jnp.ndarray, shift: int = WGT_FRAC) -> jnp.ndarray:
    """int32 accumulator -> int16, round-half-up right shift + saturation.

    ``(acc + 2^(shift-1)) >> shift`` with an arithmetic shift — the single
    rounding an FPGA MAC array applies when narrowing the wide accumulator
    back to the 16-bit datapath.  Usable inside Pallas kernel bodies (pure
    jnp integer ops).  Mirrored bit-for-bit by :func:`requantize_np`.
    """
    half = jnp.int32(1 << (shift - 1))
    return jnp.clip((acc.astype(jnp.int32) + half) >> shift,
                    -INT16_LIM, INT16_LIM).astype(jnp.int16)


def requantize_np(acc: np.ndarray, shift: int = WGT_FRAC) -> np.ndarray:
    """Independent NumPy mirror of :func:`requantize` (oracle side)."""
    half = np.int32(1 << (shift - 1))
    return np.clip((acc.astype(np.int32) + half) >> shift,
                   -INT16_LIM, INT16_LIM).astype(np.int16)


def sat_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Saturating int16 add (bias adds) — widen to int32, clip, narrow."""
    s = a.astype(jnp.int32) + b.astype(jnp.int32)
    return jnp.clip(s, -INT16_LIM, INT16_LIM).astype(jnp.int16)


def quantize_params_int(params):
    """f32 param pytree -> int16: weights Q1.14, biases Q7.8.

    Matches the layout of ``models.cnn`` params ({"conv": [{"w", "b"}...],
    "fc": [...]}) but works on any pytree of dicts with "w"/"b" leaves.
    """
    from jax.tree_util import tree_map_with_path

    def leaf(path, v):
        name = getattr(path[-1], "key", None) if path else None
        if name not in ("w", "b"):
            # Fail loudly: defaulting an unknown leaf to either format
            # would be a silent 2^6 scale error in the int16 model.
            raise ValueError(
                f"quantize_params_int expects 'w'/'b' dict leaves, got "
                f"leaf path {jax.tree_util.keystr(path)!r}")
        return to_fixed(v, WGT_FRAC if name == "w" else ACT_FRAC)

    return tree_map_with_path(leaf, params)
