"""16-bit fixed-point simulation (paper §IV: Q-format 16b weights/acts/grads).

The FPGA uses 16-bit fixed point for activations, weights and gradients.  The
TPU-native numeric is bf16; to validate that the paper's precision choice is
sound on the reproduced CNN we provide a fake-quantization path: values are
snapped to a Qm.n grid after every layer, in f32 carriers (straight-through
estimator for the BP phase, matching how the FPGA truncates products).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def make_quantizer(int_bits: int = 7, frac_bits: int = 8):
    """Q``int_bits``.``frac_bits`` symmetric fixed-point fake-quantizer.

    Default Q7.8 (1 sign + 7 int + 8 frac = 16 bits), range (-128, 128),
    resolution 2^-8 — the natural choice for the paper's CNN whose
    activations stay within +-tens.
    """
    scale = float(2 ** frac_bits)
    lim = float(2 ** (int_bits + frac_bits) - 1)

    @jax.custom_vjp
    def q(x):
        return jnp.clip(jnp.round(x * scale), -lim, lim) / scale

    # Straight-through: the FPGA truncates products but propagates gradient
    # signals at full local fidelity across the quantization.
    q.defvjp(lambda x: (q(x), None), lambda _, g: (g,))
    return q


fxp16 = make_quantizer(7, 8)


def quantize_tree(tree, int_bits: int = 7, frac_bits: int = 8):
    """Fake-quantize every leaf of a parameter pytree to Qm.n."""
    q = make_quantizer(int_bits, frac_bits)
    return jax.tree.map(q, tree)
