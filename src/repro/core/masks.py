"""Bit-packed residual masks — the paper's memory optimization (§III.D, Table II).

The FPGA design stores, per ReLU, a 1-bit mask (sign of the forward
pre-activation) in BRAM, and per 2x2 max-pool, a 2-bit argmax index.  On TPU
the analogue is a bit-packed ``uint8`` tensor living in HBM as the *only*
residual the attribution backward pass keeps — 16x smaller than a bf16
activation (32x vs f32) for ReLU masks, and 8x smaller than a bf16 index for
pool indices.

All helpers operate on the LAST axis and are pure ``jnp`` (shardable on any
leading axis, differentiable-free, jit/pjit friendly).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_BIT_WEIGHTS = np.asarray([1, 2, 4, 8, 16, 32, 64, 128], dtype=np.uint8)
_CRUMB_WEIGHTS = np.asarray([1, 4, 16, 64], dtype=np.uint8)  # 2-bit fields


def _pad_to(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = x.shape[-1]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, pad)


def pack_mask(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a boolean tensor into uint8, 8 bits per byte, along the last axis.

    ``bits`` may have any shape; the last axis is padded to a multiple of 8.
    Returns shape ``bits.shape[:-1] + (ceil(n/8),)`` uint8.
    """
    b = _pad_to(bits.astype(jnp.uint8), 8)
    b = b.reshape(b.shape[:-1] + (b.shape[-1] // 8, 8))
    return jnp.sum(b * jnp.asarray(_BIT_WEIGHTS), axis=-1, dtype=jnp.uint8)


def unpack_mask(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_mask`; returns a bool tensor with last axis ``n``."""
    bits = (packed[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & jnp.uint8(1)
    bits = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * 8,))
    return bits[..., :n].astype(jnp.bool_)


def pack_crumbs(idx: jnp.ndarray) -> jnp.ndarray:
    """Pack values in [0, 3] into uint8, 4 per byte, along the last axis.

    This is the paper's 2-bit max-pool argmax index (Fig. 5b).
    """
    c = _pad_to(idx.astype(jnp.uint8), 4)
    c = c.reshape(c.shape[:-1] + (c.shape[-1] // 4, 4))
    return jnp.sum(c * jnp.asarray(_CRUMB_WEIGHTS), axis=-1, dtype=jnp.uint8)


def unpack_crumbs(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_crumbs`; returns int32 values in [0, 3]."""
    shifts = jnp.asarray([0, 2, 4, 6], dtype=jnp.uint8)
    vals = (packed[..., None] >> shifts) & jnp.uint8(3)
    vals = vals.reshape(packed.shape[:-1] + (packed.shape[-1] * 4,))
    return vals[..., :n].astype(jnp.int32)


def mask_nbytes(shape) -> int:
    """Bytes of a packed 1-bit mask for a tensor of ``shape``."""
    n = int(np.prod(shape))
    return (n + 7) // 8


def crumb_nbytes(shape) -> int:
    """Bytes of a packed 2-bit index tensor for ``shape`` windows."""
    n = int(np.prod(shape))
    return (n + 3) // 4
