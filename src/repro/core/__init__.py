# The paper's primary contribution: gradient-backprop feature attribution
# (Saliency / DeconvNet / Guided-BP) as a composable JAX engine with the
# mask-based residual memory optimization.
from repro.core import (attribution, fidelity, fixedpoint, masks, residuals,
                        rules)
from repro.core.attribution import (attribute, attribute_classes,
                                    attribute_tokens, contrastive,
                                    fold_batched_gradients, heatmap,
                                    input_x_gradient, integrated_gradients,
                                    smoothgrad)
from repro.core.rules import METHODS, act, maxpool2x2, relu, silu

__all__ = [
    "attribution", "fidelity", "fixedpoint", "masks", "residuals", "rules",
    "attribute", "attribute_classes", "attribute_tokens", "contrastive",
    "fold_batched_gradients", "heatmap", "input_x_gradient",
    "integrated_gradients", "smoothgrad", "METHODS",
    "act", "maxpool2x2", "relu", "silu",
]
