"""DEPRECATED free-function surface over :mod:`repro.engine.methods`.

The attribution math moved to :mod:`repro.engine` — the compile-once
configure -> build -> explain API that resolves method x precision x
backward-backend x target-fanout in ONE place::

    from repro.engine import CNNModel, EngineSpec, build
    eng = build(EngineSpec(model=CNNModel(params, cfg), method="guided",
                           precision="fxp16"))
    logits, rel = eng.explain(x)

These names stay importable indefinitely for existing call sites and for
quick one-off use on a raw callable (they are pure re-exports — behavior
and signatures are unchanged, ``backward=`` knob included), but new code
and anything serving traffic should construct an engine: the spec is where
batching, caching, and backend selection are decided once instead of
per call.  Deprecation policy: shims are kept until a major-version bump
and emit no warnings (they ARE the engine's own functions).
"""
from repro.engine.methods import (METHODS, attribute,  # noqa: F401
                                  attribute_classes, attribute_tokens,
                                  contrastive, fold_batched_gradients,
                                  heatmap, input_x_gradient,
                                  integrated_gradients, output_seed,
                                  smoothgrad)

__all__ = [
    "METHODS", "attribute", "attribute_classes", "attribute_tokens",
    "contrastive", "fold_batched_gradients", "heatmap", "input_x_gradient",
    "integrated_gradients", "output_seed", "smoothgrad",
]
